"""PR 4 unified WaveEngine: pipelined-vs-sequential differential equality,
the HLO collective matrix for all three disciplines, the ONE shared
post-enqueue-peak overflow check, and a hypothesis property test driving
random mixed op/JOIN/LEAVE schedules through every discipline against its
host oracle."""
import numpy as np

from _hyp import given, settings, strategies as st
from multidev import run_multidev

# --------------------------------------------------------------------------
# Acceptance: pipelined == sequential == step loop, op-by-op, all three
# disciplines, on 8 devices.
# --------------------------------------------------------------------------
PIPELINED_DIFFERENTIAL = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import DeviceQueue, DeviceStack, DevicePriorityQueue

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(29)
K, L = 7, 8
n = 8 * L

CASES = [
    ("queue", lambda p: DeviceQueue(mesh, "data", cap=64, payload_width=2,
                                    ops_per_shard=L, pipelined=p), False),
    ("stack", lambda p: DeviceStack(mesh, "data", cap=64, payload_width=2,
                                    ops_per_shard=L, slot_depth=8,
                                    pipelined=p), False),
    ("pqueue", lambda p: DevicePriorityQueue(
        mesh, "data", n_prios=3, cap=64, payload_width=2, ops_per_shard=L,
        pipelined=p), True),
]
for name, make, has_prio in CASES:
    seq, pipe = make(False), make(True)
    E = rng.random((K, n)) < 0.6
    V = rng.random((K, n)) < 0.9
    PW = rng.integers(0, 999, (K, n, 2)).astype(np.int32)
    args = [jnp.array(E), jnp.array(V)]
    if has_prio:
        args.append(jnp.array(rng.integers(0, 3, (K, n)), jnp.int32))
    args.append(jnp.array(PW))
    # reference: K host-driven sequential single waves
    st_ref = seq.init_state()
    ref = []
    for k in range(K):
        st_ref, *o = seq.step(st_ref, *(a[k] for a in args))
        ref.append([np.asarray(x) for x in o])
    for mode, q in (("sequential", seq), ("pipelined", pipe)):
        sa, *oa = q.run_waves(q.init_state(), *args)
        oa = [np.asarray(x) for x in oa]
        for k in range(K):
            for a, b in zip(oa, ref[k]):
                assert (a[k] == b).all(), (name, mode, k)
        fa = jax.tree.leaves(sa)
        fb = jax.tree.leaves(st_ref)
        for a, b in zip(fa, fb):
            assert (np.asarray(a) == np.asarray(b)).all(), (name, mode)
    print("OK", name, "pipelined == sequential == step loop")
"""


def test_pipelined_matches_sequential_all_disciplines_8dev():
    """Acceptance: the software-pipelined burst schedule is bit-identical
    to the sequential one (and to K host-driven steps) for the FIFO, LIFO
    and priority disciplines — outputs AND final state."""
    out = run_multidev(PIPELINED_DIFFERENTIAL, n_dev=8)
    for name in ("queue", "stack", "pqueue"):
        assert f"OK {name} pipelined == sequential == step loop" in out


# --------------------------------------------------------------------------
# CI satellite: the HLO collective matrix.  The pipelined K-wave program
# must keep <= 2 all_to_all per wave for queue, stack AND priority — it
# actually has ONE in the scan body (fused request_k ‖ reply_{k-1}) plus a
# single drain epilogue, i.e. 2 static / (K+1)/K per wave amortized.
# --------------------------------------------------------------------------
HLO_MATRIX = r"""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import DeviceQueue, DeviceStack, DevicePriorityQueue
from repro.analysis import count_all_to_all

mesh = make_mesh((8,), ("data",))
K, L = 6, 4
n = 8 * L
for name, make, has_prio in (
    ("queue", lambda p: DeviceQueue(mesh, "data", cap=32, payload_width=2,
                                    ops_per_shard=L, pipelined=p), False),
    ("stack", lambda p: DeviceStack(mesh, "data", cap=32, payload_width=2,
                                    ops_per_shard=L, pipelined=p), False),
    ("priority", lambda p: DevicePriorityQueue(
        mesh, "data", n_prios=2, cap=32, payload_width=2, ops_per_shard=L,
        pipelined=p), True),
):
    seq, pipe = make(False), make(True)
    for tag, q in (("seq", seq), ("pipe", pipe)):
        args = [q.init_state(), jnp.zeros((K, n), bool),
                jnp.zeros((K, n), bool)]
        if has_prio:
            args.append(jnp.zeros((K, n), jnp.int32))
        args.append(jnp.zeros((K, n, 2), jnp.int32))
        c = count_all_to_all(q._run_waves, tuple(args))
        if tag == "seq":
            # sequential scan body: request + reply = 2 per wave
            assert c == 2, f"{name} sequential run_waves has {c}"
        else:
            # pipelined: ONE fused a2a in the body + one drain epilogue;
            # the per-wave bound <= 2 holds with room to spare
            assert c <= 2, f"{name} pipelined run_waves has {c}"
        print(f"OK hlo {name} {tag}: {c}")
"""


def test_pipelined_hlo_collective_matrix_8dev():
    """Satellite: the pipelined path keeps <= 2 all_to_all per wave for
    queue, stack, AND priority (static count: 1 fused collective in the
    scan body + 1 drain epilogue for the whole burst)."""
    out = run_multidev(HLO_MATRIX, n_dev=8)
    for name in ("queue", "stack", "priority"):
        assert f"OK hlo {name} seq: 2" in out
        assert f"OK hlo {name} pipe:" in out


# --------------------------------------------------------------------------
# Satellite: THE post-enqueue-peak overflow check lives once in
# wave_engine.post_enqueue_peak_overflow (it was patched three times in
# PR 3: fused queue, legacy queue, priority queue).  One regression test
# covers overflow surfacing for all three disciplines through the engine.
# --------------------------------------------------------------------------
def test_overflow_surfaces_once_for_all_disciplines():
    """With a queue/tier at exact capacity, a same-wave enq+deq transiently
    exceeds the store (PUTs apply before GETs), so the flag must check the
    post-enqueue peak, not the post-wave size — for the fused FIFO wave,
    the legacy five-collective wave, and the priority wave alike.  The
    stack's capacity hazard is commit-time (depth exhaustion) and must
    surface through the same per-wave overflow output."""
    import jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.dqueue import DevicePriorityQueue, DeviceQueue, DeviceStack

    mesh = make_mesh((1,), ("data",))
    one = jnp.ones((4, 1), jnp.int32)
    fill = jnp.array([True, True, False, False])
    e = jnp.array([True, False, False, False])
    v = jnp.array([True, True, False, False])  # 1 enq + 1 deq: peak = 3

    for fused in (True, False):                # engine AND legacy paths
        dq = DeviceQueue(mesh, "data", cap=2, payload_width=1,
                         ops_per_shard=4, fused=fused)
        st = dq.init_state()
        st, _, _, _, _, ovf = dq.step(st, fill, fill, one)
        assert not bool(ovf), fused            # 2 live == capacity: fine
        st, _, _, _, _, ovf = dq.step(st, e, v, one)
        assert bool(ovf), ("post-enqueue peak went undetected", fused)

    pq = DevicePriorityQueue(mesh, "data", n_prios=2, cap=2,
                             payload_width=1, ops_per_shard=4)
    ps = pq.init_state()
    tier1 = jnp.ones((4,), jnp.int32)
    ps, *_, ovf, _ = pq.step(ps, fill, fill, tier1, one)
    assert not bool(ovf)
    ps, *_, ovf, _ = pq.step(ps, e, v, tier1, one)
    assert bool(ovf), "tier-level post-enqueue peak went undetected"

    # stack: two pushes fill cap=1 x depth=2; a third push has no free
    # depth entry -> the commit-time slot overflow must surface
    ds = DeviceStack(mesh, "data", cap=1, payload_width=1, ops_per_shard=4,
                     slot_depth=2)
    ss = ds.init_state()
    ss, *_, ovf = ds.step(ss, fill, fill, one)
    assert not bool(ovf)
    ss, *_, ovf = ds.step(ss, e, e, one)       # third push: depth exhausted
    assert bool(ovf), "stack depth exhaustion went undetected"


# --------------------------------------------------------------------------
# Satellite: hypothesis property test — a random mixed op/JOIN/LEAVE
# schedule through the unified engine, all three disciplines, against the
# host oracles (Skueue protocol sim for FIFO/LIFO order through membership
# changes, PriorityOracle for the tier semantics).
# --------------------------------------------------------------------------
PROPERTY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.protocol import DEQ, ENQ, Skueue
from repro.core.priority import DEQ as PDEQ, ENQ as PENQ, PriorityOracle
from repro.core.seap import DEQ as SDEQ, ENQ as SENQ, SeapOracle
from repro.dqueue import (ElasticDeviceQueue, ElasticDeviceStack,
                          ElasticDevicePriorityQueue, ElasticDeviceSeapQueue)

OPS = %(ops)r
PRIOS = %(prios)r
KEYS = %(keys)r
SCHEDULE = %(schedule)r
P_ = %(n_prios)d
RELAX = %(relax)d
L = 4
B_ = 4
SPLIT_OCC = 6


def run_device(elastic, W, codes=None):
    outs = []
    cut = sorted(SCHEDULE) + [len(OPS)]
    start = 0
    for end in cut:
        chunk = OPS[start:end]
        if chunk:
            n = elastic.n_shards * elastic.L
            K = -(-len(chunk) // n)
            E = np.zeros((K, n), bool)
            V = np.zeros((K, n), bool)
            PR = np.zeros((K, n), np.int32)
            PW = np.zeros((K, n, W), np.int32)
            for j, op in enumerate(chunk):
                k, i = divmod(j, n)
                E[k, i] = bool(op)
                V[k, i] = True
                if codes is not None:
                    PR[k, i] = codes[start + j]
                PW[k, i, 0] = start + j
            if codes is not None:
                tier, pos, m, dv, dok, ovf, _ = elastic.run_waves(E, V, PR,
                                                                  PW)
            else:
                pos, m, dv, dok, ovf = elastic.run_waves(E, V, PW)
                tier = pos
            assert not np.asarray(ovf).any()
            pos = np.asarray(pos).reshape(-1)[:len(chunk)]
            m = np.asarray(m).reshape(-1)[:len(chunk)]
            tier = np.asarray(tier).reshape(-1)[:len(chunk)]
            dv = np.asarray(dv).reshape(K * n, W)[:len(chunk)]
            dok = np.asarray(dok).reshape(-1)[:len(chunk)]
            for j, op in enumerate(chunk):
                res = None
                if (not op) and m[j]:
                    assert dok[j], f"matched op {start + j} lost its element"
                    res = int(dv[j, 0])
                outs.append((int(pos[j]), bool(m[j]), res, int(tier[j])))
        if end in SCHEDULE:
            kind, arg = SCHEDULE[end]
            s = (elastic.grow(arg) if kind == "grow"
                 else elastic.shrink(arg))
            assert s["moved"] == elastic.size, (s, elastic.size)
        start = end
    return outs


def run_protocol(mode):
    sk = Skueue(4, mode=mode, seed=0, local_combining=False)
    nid = sk.ring.node_ids()[0]
    rids = []

    def inject(s, rnd):
        i = rnd - 1
        if i < len(OPS):
            rids.append(s.inject(nid, ENQ if OPS[i] else DEQ))
        if i in SCHEDULE:
            kind, arg = SCHEDULE[i]
            if kind == "grow":
                for _ in range(arg):
                    s.request_join()
            else:
                keep = s.ring.proc[nid]
                alive = sorted({s.ring.proc[v] for v in s.ring.node_ids()})
                for pid in [p for p in alive if p != keep][:len(arg)]:
                    s.request_leave(pid)

    sk.run_rounds(len(OPS) + 80, inject_fn=inject)
    assert all(sk.requests[r].done for r in rids)
    return [(sk.requests[r].pos if sk.requests[r].pos is not None else -1,
             not (sk.requests[r].kind == DEQ
                  and sk.requests[r].result == -1),
             sk.requests[r].result
             if sk.requests[r].kind == DEQ and sk.requests[r].result != -1
             else None)
            for r in rids]


# ---- FIFO and LIFO vs the Skueue protocol sim through JOIN/LEAVE ----
for mode, cls, kw in (("queue", ElasticDeviceQueue, {}),
                      ("stack", ElasticDeviceStack, {"slot_depth": 8})):
    eq = cls(4, cap=32, payload_width=2, ops_per_shard=L, **kw)
    dev = run_device(eq, 2)
    ref = run_protocol(mode)
    assert [d[0] for d in dev] == [r[0] for r in ref], f"{mode} positions"
    assert [d[1] for d in dev] == [r[1] for r in ref], f"{mode} matched"
    assert [d[2] for d in dev] == [r[2] for r in ref], f"{mode} results"
    print(f"OK property {mode}")

# ---- priority vs the host P-tier oracle (membership-oblivious) ----
eq = ElasticDevicePriorityQueue(4, n_prios=P_, relaxation=RELAX, cap=32,
                                payload_width=2, ops_per_shard=L)
dev = run_device(eq, 2, codes=PRIOS)
# replay the SAME wave partitioning run_device used (the shard count at
# the time each chunk ran) through the membership-oblivious oracle
cut = sorted(SCHEDULE) + [len(OPS)]
oracle = PriorityOracle(P_, relaxation=RELAX)
recs = []
start = 0
shards = 4
for end in cut:
    chunk = OPS[start:end]
    if chunk:
        n = shards * L
        K = -(-len(chunk) // n)
        for k in range(K):
            wave = []
            for i in range(n):
                j = k * n + i
                if j >= len(chunk):
                    wave.append(None)
                elif chunk[j]:
                    wave.append((PENQ, PRIOS[start + j], start + j, i // L))
                else:
                    wave.append((PDEQ, 0, None, i // L))
            recs.extend(r for r in oracle.wave(wave, n_shards=shards)
                        [:len(chunk) - k * n])
    if end in SCHEDULE:
        kind, arg = SCHEDULE[end]
        shards += arg if kind == "grow" else -len(arg)
    start = end
assert len(recs) == len(dev) == len(OPS)
for j, (d, r) in enumerate(zip(dev, recs)):
    assert d[1] == r.matched, ("pqueue matched", j)
    assert d[0] == r.pos, ("pqueue pos", j)
    if r.matched:
        assert d[3] == r.tier, ("pqueue tier", j)
    if r.matched and r.value is not None:
        assert d[2] == r.value, ("pqueue value", j)
assert eq.sizes == oracle.sizes
print("OK property pqueue")

# ---- seap (arbitrary keys) vs the host bucket-directory oracle ----
eq = ElasticDeviceSeapQueue(4, n_buckets=B_, split_occupancy=SPLIT_OCC,
                            cap=32, payload_width=2, ops_per_shard=L)
dev = run_device(eq, 2, codes=KEYS)
cut = sorted(SCHEDULE) + [len(OPS)]
oracle = SeapOracle(B_, split_occupancy=SPLIT_OCC)
recs = []
start = 0
shards = 4
for end in cut:
    chunk = OPS[start:end]
    if chunk:
        n = shards * L
        K = -(-len(chunk) // n)
        for k in range(K):
            wave = []
            for i in range(n):
                j = k * n + i
                if j >= len(chunk):
                    wave.append(None)
                elif chunk[j]:
                    wave.append((SENQ, KEYS[start + j], start + j))
                else:
                    wave.append((SDEQ, 0, None))
            recs.extend(oracle.wave(wave)[:len(chunk) - k * n])
    if end in SCHEDULE:
        kind, arg = SCHEDULE[end]
        shards += arg if kind == "grow" else -len(arg)
    start = end
assert len(recs) == len(dev) == len(OPS)
for j, (d, r) in enumerate(zip(dev, recs)):
    assert d[1] == r.matched, ("seap matched", j)
    assert d[0] == r.pos, ("seap pos", j)
    if r.matched:
        assert d[3] == r.bucket, ("seap bucket", j)
    if r.matched and r.value is not None:
        assert d[2] == r.value, ("seap value", j)
assert eq.sizes == oracle.sizes
assert eq.directory() == oracle.directory()
print("OK property seap")
"""


@settings(max_examples=2, deadline=None)
@given(st.lists(st.booleans(), min_size=16, max_size=40),
       st.integers(0, 2 ** 31 - 1), st.integers(0, 2), st.integers(0, 1))
def test_random_mixed_membership_schedule_matches_oracles_8dev(
        ops, seed, n_events, relax):
    """Satellite property test: a randomized mixed enq/deq trace with a
    randomized JOIN/LEAVE schedule produces, through the unified engine,
    exactly the host oracles' positions, ⊥ sets, results, tiers and
    buckets — for all FOUR disciplines on 8 devices (PR 5 adds the Seap
    arbitrary-key discipline against its bucket-directory oracle)."""
    rng = np.random.default_rng(seed)
    n_prios = int(rng.integers(2, 4))
    prios = [int(p) for p in rng.integers(0, n_prios, len(ops))]
    keys = [int(k) for k in rng.integers(-1000, 1000, len(ops))]
    schedule = {}
    shards = 4
    for idx in sorted(rng.choice(np.arange(1, max(2, len(ops))),
                                 size=n_events, replace=False).tolist()):
        if rng.random() < 0.5 and shards <= 6:
            k = int(rng.integers(1, min(2, 8 - shards) + 1))
            schedule[int(idx)] = ("grow", k)
            shards += k
        elif shards >= 3:
            m = int(rng.integers(1, min(2, shards - 2) + 1))
            ids = sorted(rng.choice(np.arange(shards), size=m,
                                    replace=False).tolist())
            schedule[int(idx)] = ("shrink", [int(i) for i in ids])
            shards -= m
    script = PROPERTY % {"ops": [bool(o) for o in ops], "prios": prios,
                         "keys": keys, "schedule": schedule,
                         "n_prios": n_prios, "relax": int(relax)}
    out = run_multidev(script, n_dev=8)
    assert "OK property queue" in out
    assert "OK property stack" in out
    assert "OK property pqueue" in out
    assert "OK property seap" in out


# --------------------------------------------------------------------------
# PR 7 Wavescope: telemetry-on legs of the HLO matrix.  Metrics must add
# ZERO collectives (static a2a count identical on vs off for step,
# sequential burst AND pipelined burst, all four disciplines) and must not
# perturb results (outputs and final state bit-identical on vs off).
# --------------------------------------------------------------------------
TELEMETRY_MATRIX = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import (DeviceQueue, DeviceStack, DevicePriorityQueue,
                          DeviceSeapQueue)
from repro.analysis import count_all_to_all

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(5)
K, L = 5, 4
n = 8 * L

CASES = [
    ("queue", lambda p, m: DeviceQueue(
        mesh, "data", cap=32, payload_width=2, ops_per_shard=L,
        pipelined=p, metrics=m), 0),
    ("stack", lambda p, m: DeviceStack(
        mesh, "data", cap=32, payload_width=2, ops_per_shard=L,
        slot_depth=8, pipelined=p, metrics=m), 0),
    ("priority", lambda p, m: DevicePriorityQueue(
        mesh, "data", n_prios=2, cap=32, payload_width=2, ops_per_shard=L,
        pipelined=p, metrics=m), 2),
    ("seap", lambda p, m: DeviceSeapQueue(
        mesh, "data", n_buckets=4, cap=32, payload_width=2,
        ops_per_shard=L, pipelined=p, metrics=m), 50),
]
for name, make, kmax in CASES:
    E = rng.random((K, n)) < 0.6
    V = rng.random((K, n)) < 0.9
    args = [jnp.array(E), jnp.array(V)]
    if kmax:
        args.append(jnp.array(rng.integers(0, kmax, (K, n)), jnp.int32))
    args.append(jnp.array(rng.integers(0, 999, (K, n, 2)), jnp.int32))
    step_args = tuple(a[0] for a in args)
    args = tuple(args)

    # --- static collective counts: telemetry adds ZERO, all three modes
    q_off, q_on = make(True, False), make(True, True)
    c_off = count_all_to_all(q_off._step, (q_off.init_state(),) + step_args)
    c_on = count_all_to_all(
        q_on._step,
        ((q_on.init_state(), q_on.engine.init_metrics_state()),)
        + step_args)
    assert c_on == c_off == 2, (name, "step", c_off, c_on)
    print(f"OK obs-hlo {name} step: off={c_off} on={c_on}")
    for tag, pipe in (("seq", False), ("pipe", True)):
        q_off, q_on = make(pipe, False), make(pipe, True)
        c_off = count_all_to_all(q_off._run_waves,
                                 (q_off.init_state(),) + args)
        c_on = count_all_to_all(
            q_on._run_waves,
            ((q_on.init_state(), q_on.engine.init_metrics_state()),) + args)
        assert c_on == c_off <= 2, (name, tag, c_off, c_on)
        print(f"OK obs-hlo {name} {tag}: off={c_off} on={c_on}")

    # --- bit-identity: metrics-on run == metrics-off run (outputs AND
    #     final state), pipelined burst
    q_off, q_on = make(True, False), make(True, True)
    s_off, *o_off = q_off.run_waves(q_off.init_state(), *args)
    s_on, *o_on = q_on.run_waves(q_on.init_state(), *args)
    for a, b in zip(o_off, o_on):
        assert (np.asarray(a) == np.asarray(b)).all(), name
    for a, b in zip(jax.tree.leaves(s_off), jax.tree.leaves(s_on)):
        assert (np.asarray(a) == np.asarray(b)).all(), name
    rows = q_on.drain_metrics()
    assert len(rows) == K, (name, len(rows))
    assert [r["seq"] for r in rows] == list(range(K)), name
    occ_w = {"queue": 1, "stack": 1, "priority": 2, "seap": 4}[name]
    assert all(len(r["occ"]) == occ_w for r in rows), name
    print(f"OK obs-id {name}: outputs+state bit-identical, {len(rows)} rows")
"""


def test_telemetry_hlo_matrix_and_bit_identity_8dev():
    """PR 7 acceptance: Wavescope metrics keep the collective budget
    (all_to_all count identical with telemetry on vs off for step /
    sequential burst / pipelined burst, all four disciplines) and results
    are bit-identical with telemetry on vs off."""
    out = run_multidev(TELEMETRY_MATRIX, n_dev=8, timeout=900)
    for name in ("queue", "stack", "priority", "seap"):
        assert f"OK obs-hlo {name} step: off=2 on=2" in out
        assert f"OK obs-hlo {name} seq: off=2 on=2" in out
        assert f"OK obs-hlo {name} pipe:" in out
        assert f"OK obs-id {name}" in out


# --------------------------------------------------------------------------
# PR 7 Wavescope: the flight recorder attaches the occupancy trajectory to
# QueueOverflowError, and the trajectory is consistent with a host replay
# of its own puts/gets counters.
# --------------------------------------------------------------------------
def test_flight_recorder_trajectory_on_overflow():
    """Drive an elastic FIFO with telemetry into a deliberate overflow:
    the raised QueueOverflowError must carry the last-K wave summaries,
    whose occupancies replay exactly from the recorded puts/gets."""
    import numpy as np
    import pytest
    from repro.dqueue import ElasticDeviceQueue, QueueOverflowError

    q = ElasticDeviceQueue(1, cap=8, payload_width=1, ops_per_shard=4,
                           metrics=True)
    # each wave: 3 puts + 1 get = net +2; with per-window capacity 8 the
    # post-enqueue peak first exceeds capacity on wave 3 (6 live + 3 puts)
    is_enq = np.array([True, True, True, False])
    valid = np.ones(4, bool)
    payload = np.arange(4, dtype=np.int32).reshape(4, 1)
    with pytest.raises(QueueOverflowError) as ei:
        for _ in range(10):
            q.step(is_enq, valid, payload)
    err = ei.value
    assert err.trajectory, "overflow must carry the flight recorder"
    assert err.trajectory == q.trajectory()
    assert "flight recorder" in str(err)
    # host replay: occupancy must integrate the recorded puts - gets
    occ = 0
    for r in err.trajectory:
        occ += r["puts"] - r["gets"]
        assert r["occ"] == [occ], err.trajectory
        assert r["headroom"] == 8 - occ
    # the failing wave is the last summary, already past capacity's edge
    assert occ + 3 > 8 or occ > 8
