"""PR 3 priority tiers: DevicePriorityQueue differential vs. the host
P-tier oracle (op-by-op, P in {2, 4}, across grow+shrink migrations),
HLO collective count, bounded relaxation, and serve/fault/checkpoint
integration."""
import numpy as np
import pytest

from multidev import run_multidev

DIFFERENTIAL = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.priority import DEQ, ENQ, PriorityOracle
from repro.dqueue import ElasticDevicePriorityQueue

# randomized mixed enq/deq schedule with random tiers; migration schedule
# applied between waves (one grow, one shrink) — the oracle is membership-
# oblivious, so op-by-op equality proves migrations lose/reorder nothing.
for P_ in (2, 4):
    for relax in (0, 1):
        eq = ElasticDevicePriorityQueue(4, n_prios=P_, relaxation=relax,
                                        cap=32, payload_width=2,
                                        ops_per_shard=4)
        oracle = PriorityOracle(P_, relaxation=relax)
        rng = np.random.default_rng(100 * P_ + relax)
        relaxed_served = 0
        for it in range(14):
            if it == 5:
                st = eq.grow(2)
                assert st["moved"] == eq.size == oracle.size, (st, it)
            if it == 10:
                st = eq.shrink([0, 3])
                assert st["moved"] == eq.size == oracle.size, (st, it)
            n = eq.n_shards * eq.L
            e = rng.random(n) < 0.55
            v = rng.random(n) < 0.9
            pr = rng.integers(0, P_, n).astype(np.int32)
            pw = np.zeros((n, 2), np.int32)
            pw[:, 0] = rng.integers(0, 1 << 20, n)
            tier, pos, m, dv, dok, ovf, nrel = eq.step(e, v, pr, pw)
            assert not bool(np.asarray(ovf))
            ops = [None if not v[i] else
                   ((ENQ, int(pr[i]), int(pw[i, 0]), i // eq.L) if e[i]
                    else (DEQ, 0, None, i // eq.L)) for i in range(n)]
            recs = oracle.wave(ops, n_shards=eq.n_shards)
            tier, pos, m, dv, dok = map(np.asarray, (tier, pos, m, dv, dok))
            for i, r in enumerate(recs):
                assert bool(m[i]) == r.matched, (P_, relax, it, i)
                assert int(tier[i]) == r.tier, (P_, relax, it, i)
                assert int(pos[i]) == r.pos, (P_, relax, it, i)
                if r.matched and r.value is not None:
                    # matched dequeue MUST find its element (none lost)
                    assert bool(dok[i]), (P_, relax, it, i)
                    assert int(dv[i, 0]) == r.value, (P_, relax, it, i)
            n_rel_oracle = sum(r.relaxed for r in recs)
            assert int(nrel) == n_rel_oracle, (P_, relax, it)
            relaxed_served += n_rel_oracle
        assert eq.sizes == oracle.sizes, (P_, relax)
        if relax == 0:
            assert relaxed_served == 0
        print(f"OK pqueue P={P_} relax={relax} sizes={oracle.sizes} "
              f"relaxed={relaxed_served}")
"""


def test_priority_queue_matches_oracle_across_migrations_8dev():
    """Acceptance: strict mode matches the P-tier host oracle op-by-op
    under a randomized mixed schedule on 8 CPU devices for P in {2, 4},
    including across one grow and one shrink migration (and the relaxed
    mode matches the oracle's bounded-relaxation rule)."""
    out = run_multidev(DIFFERENTIAL, n_dev=8)
    for P_ in (2, 4):
        for relax in (0, 1):
            assert f"OK pqueue P={P_} relax={relax}" in out


COLLECTIVES = r"""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import DevicePriorityQueue
from repro.analysis import count_all_to_all
mesh = make_mesh((8,), ("data",))
for P_, relax in ((2, 0), (4, 0), (2, 1)):
    dq = DevicePriorityQueue(mesh, "data", n_prios=P_, cap=32,
                             payload_width=2, ops_per_shard=4,
                             relaxation=relax)
    n = dq.n_shards * dq.L
    args = (dq.init_state(), jnp.zeros(n, bool), jnp.zeros(n, bool),
            jnp.zeros(n, jnp.int32), jnp.zeros((n, 2), jnp.int32))
    c = count_all_to_all(dq._step, args)
    assert c <= 2, f"P={P_} relax={relax}: {c} all-to-alls per wave"
    print(f"OK collectives P={P_} relax={relax}:", c)
"""


def test_priority_wave_lowers_to_two_all_to_alls_8dev():
    """Acceptance: the priority wave still costs <= 2 all_to_all
    collectives, for multiple tier counts and in relaxed mode."""
    out = run_multidev(COLLECTIVES, n_dev=8)
    assert "OK collectives P=2 relax=0: 2" in out
    assert "OK collectives P=4 relax=0: 2" in out
    assert "OK collectives P=2 relax=1: 2" in out


RUN_WAVES = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import DevicePriorityQueue
mesh = make_mesh((8,), ("data",))
dq = DevicePriorityQueue(mesh, "data", n_prios=3, cap=64, payload_width=2,
                         ops_per_shard=4)
n = dq.n_shards * dq.L
K = 6
rng = np.random.default_rng(41)
E = rng.random((K, n)) < 0.6
V = rng.random((K, n)) < 0.9
PR = rng.integers(0, 3, (K, n)).astype(np.int32)
PW = rng.integers(0, 99, (K, n, 2)).astype(np.int32)
sb = dq.init_state()
outs = []
for k in range(K):
    sb, *o = dq.step(sb, jnp.array(E[k]), jnp.array(V[k]), jnp.array(PR[k]),
                     jnp.array(PW[k]))
    outs.append([np.asarray(x) for x in o])
sa, *oa = dq.run_waves(dq.init_state(), jnp.array(E), jnp.array(V),
                       jnp.array(PR), jnp.array(PW))
oa = [np.asarray(x) for x in oa]
for k in range(K):
    for a, b in zip(oa, outs[k]):
        assert (a[k] == b).all(), k
assert (np.asarray(sa.firsts) == np.asarray(sb.firsts)).all()
assert (np.asarray(sa.lasts) == np.asarray(sb.lasts)).all()
assert (np.asarray(sa.store_full) == np.asarray(sb.store_full)).all()
print("OK pqueue run_waves == K steps")
"""


def test_priority_run_waves_equals_stepwise_8dev():
    out = run_multidev(RUN_WAVES, n_dev=8)
    assert "OK pqueue run_waves == K steps" in out


CHECKPOINT_FAULT = r"""
import tempfile
import numpy as np, jax
from repro.dqueue import ElasticDevicePriorityQueue
from repro.fault import FailureInjector, elastic_queue_policy, \
    run_with_restarts

# ---- fault: ShardFailure => LEAVE of the priority fabric, zero replay ----
q = ElasticDevicePriorityQueue(4, n_prios=2, cap=64, payload_width=2,
                               ops_per_shard=4)
got = []

def step_fn(state, step):
    n = q.n_shards * q.L
    e = np.zeros(n, bool); v = np.zeros(n, bool)
    pr = np.zeros(n, np.int32)
    pw = np.zeros((n, 2), np.int32)
    e[:4] = v[:4] = True
    pr[:4] = step % 2                       # alternate tiers
    pw[:4, 0] = np.arange(step * 4, step * 4 + 4)
    v[4:6] = True                           # 2 dequeues: queue grows
    _, _, _, dv, dok, _, _ = q.step(e, v, pr, pw)
    dv, dok = np.asarray(dv), np.asarray(dok)
    got.extend(int(dv[i, 0]) for i in range(n) if dok[i])
    return {"done": np.int64(step + 1)}

inj = FailureInjector(shard_fail_at={3: 1})
with tempfile.TemporaryDirectory() as d:
    state, metrics = run_with_restarts(
        init_state=lambda: {"done": np.int64(0)},
        step_fn=step_fn, n_steps=8, ckpt_dir=d, ckpt_every=100,
        injector=inj, elastic=elastic_queue_policy(q, regrow_after=2),
        log=lambda *a: None)
assert metrics["leaves"] == 1 and metrics["restarts"] == 0, metrics
assert metrics["joins"] == 1 and metrics["steps_run"] == 8, metrics
assert q.n_shards == 4
served = len(got)
while q.size > 0:
    n = q.n_shards * q.L
    _, _, _, dv, dok, _, _ = q.step(np.zeros(n, bool), np.ones(n, bool),
                                    np.zeros(n, np.int32),
                                    np.zeros((n, 2), np.int32))
    dv, dok = np.asarray(dv), np.asarray(dok)
    got.extend(int(dv[i, 0]) for i in range(n) if dok[i])
assert sorted(got) == list(range(32)), got
print("OK pqueue fault LEAVE/JOIN: zero replay, no element lost")

# ---- checkpoint cold-start reshard (per-tier layout in the manifest) ----
q2 = ElasticDevicePriorityQueue(6, n_prios=3, relaxation=1, cap=16,
                                payload_width=2, ops_per_shard=4)
n = q2.n_shards * q2.L
e = np.ones(n, bool)
pr = (np.arange(n) % 3).astype(np.int32)
pw = np.zeros((n, 2), np.int32)
pw[:, 0] = np.arange(n)
q2.step(e, e, pr, pw)
with tempfile.TemporaryDirectory() as d:
    q2.save(d, 7)
    q3 = ElasticDevicePriorityQueue.restore(d, n_shards=3)
assert q3.n_shards == 3 and q3.n_prios == 3 and q3.relaxation == 1
assert q3.sizes == q2.sizes and q3.size == n
assert q3.migrations[-1]["kind"] == "shrink"
# drain: every element survives, and each tier comes out in FIFO order
# (the restored queue keeps relaxation=1, so TIERS may interleave — that
# is the relaxation knob working; per-tier FIFO must still hold)
got = []
while len(got) < n:
    m = q3.n_shards * q3.L
    t, _, _, dv, dok, _, _ = q3.step(np.zeros(m, bool), np.ones(m, bool),
                                     np.zeros(m, np.int32),
                                     np.zeros((m, 2), np.int32))
    t, dv, dok = np.asarray(t), np.asarray(dv), np.asarray(dok)
    got.extend((int(t[i]), int(dv[i, 0])) for i in range(m) if dok[i])
for tier_id in range(3):
    per_tier = [v for t, v in got if t == tier_id]
    assert per_tier == sorted(per_tier), (tier_id, "FIFO broken in tier")
    assert per_tier == [v for v in range(n) if v % 3 == tier_id]
assert sorted(v for _, v in got) == list(range(n))
print("OK pqueue checkpoint cold-start reshard 6 -> 3")
"""


def test_priority_fault_and_checkpoint_8dev():
    """Satellite integration: shard failure LEAVEs the priority fabric via
    fault.elastic_queue_policy (zero replayed steps, no element lost), and
    checkpoint manifests carry the per-tier layout so a cold start can
    reshard (n_prios/relaxation restored, priority order intact)."""
    out = run_multidev(CHECKPOINT_FAULT, n_dev=8)
    assert "OK pqueue fault LEAVE/JOIN" in out
    assert "OK pqueue checkpoint cold-start reshard" in out


def test_priority_scan_pallas_matches_core():
    """kernels/segscan extension: the pallas-path P-tier assignment equals
    core.scan_queue.priority_queue_scan (strict mode) on random batches."""
    import jax.numpy as jnp
    from repro.core.scan_queue import priority_queue_scan
    from repro.kernels.segscan import priority_queue_scan_pallas

    rng = np.random.default_rng(2)
    for P_ in (2, 4):
        n = 96
        is_enq = jnp.array(rng.random(n) < 0.5)
        valid = jnp.array(rng.random(n) < 0.9)
        prio = jnp.array(rng.integers(0, P_, n), jnp.int32)
        firsts = jnp.array(rng.integers(0, 5, P_), jnp.int32)
        lasts = firsts + jnp.array(rng.integers(-1, 6, P_), jnp.int32)
        ref = priority_queue_scan(is_enq, prio, valid, firsts, lasts,
                                  n_prios=P_)
        out = priority_queue_scan_pallas(is_enq, prio, valid, firsts,
                                         lasts, P_)
        for a, b in zip(out, ref[:5]):
            assert (np.asarray(a) == np.asarray(b)).all(), P_


# The post-enqueue-peak overflow regression moved to
# tests/test_wave_engine.py::test_overflow_surfaces_once_for_all_disciplines
# when the check itself was deduplicated into
# wave_engine.post_enqueue_peak_overflow (PR 4): one helper, one test,
# all three disciplines.


def test_priority_oracle_rejects_bad_tier():
    from repro.core.priority import ENQ, PriorityOracle
    with pytest.raises(ValueError):
        PriorityOracle(0)
    orc = PriorityOracle(2)
    with pytest.raises(ValueError):
        orc.wave([(ENQ, 5, 1, 0)])


SERVE_PRIORITY = r"""
import numpy as np, jax
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve import Request, ServeEngine

cfg = get_config("mamba2_130m").reduced(n_layers=1)
model = build_model(cfg)
params, _ = model.init_params(jax.random.key(0))
eng = ServeEngine(model, params, make_host_mesh(n_data=2), max_slots=2,
                  max_seq=16, priorities=2)
batch = [Request(rid=i, prompt=[1, 2], max_new=2, prio=1) for i in range(6)]
inter = [Request(rid=100 + i, prompt=[3, 4], max_new=2) for i in range(3)]
eng.submit(batch)                 # batch flood staged first
eng.submit(inter, prio=0)         # interactive arrives later, same step
assert eng.run_until_drained(max_steps=400)
assert eng.stats["served"] == 9
# interactive admitted ahead of every batch request despite arriving later
i_starts = [r.start_step for r in inter]
b_starts = [r.start_step for r in batch]
assert max(i_starts) <= min(b_starts), (i_starts, b_starts)
# batch requests keep FIFO order WITHIN their tier
assert b_starts == sorted(b_starts), b_starts
st = eng.tier_wait_stats()
assert st[0]["p99"] <= st[1]["p50"], st  # tier separation is visible
# live resize of the priority fabric mid-traffic
eng.submit([Request(rid=200 + i, prompt=[5], max_new=2,
                    prio=i % 2) for i in range(4)])
eng.step()
mig = eng.resize(1)
assert mig["P_to"] == 1 and eng.queue.n_shards == 1
assert eng.run_until_drained(max_steps=400)
assert eng.stats["served"] == 13
print("OK serve priorities", st)
"""


def test_serve_engine_priorities_8dev():
    """ServeEngine(priorities=2): interactive admitted ahead of batch
    traffic in the fused wave, per-tier latency reported, live resize of
    the priority fabric under traffic."""
    out = run_multidev(SERVE_PRIORITY, n_dev=8)
    assert "OK serve priorities" in out
