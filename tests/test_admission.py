"""Admission control plane (PR 8): policies, pressure signal, controller.

Host-level tests drive the policies against hand-built PressureSignals
(no devices needed); the integration block runs every policy against a
real EDF (deadline=True) ServeEngine, pinning the tentpole guarantee:
with a policy installed, overload surfaces as structured, retryable
AdmissionRejected at the submit edge — never QueueOverflowError from
the wave.
"""
import pytest

import jax

from repro.serve.admission import (AdmissionPolicy, DeferPolicy,
                                   DegradePolicy, PressureSignal,
                                   ShedPolicy, resolve_policy)
from repro.serve.controller import ControllerConfig, HysteresisController


class R:
    """Light Request stand-in: policies only read prio/deadline/rid."""

    def __init__(self, rid, prio=0, deadline=-1):
        self.rid, self.prio, self.deadline = rid, prio, deadline

    def __repr__(self):
        return f"R({self.rid})"


def fifo_sig(cap=8, occ=0, staged=0, spill=0, spill_cap=4, step=0):
    return PressureSignal(capacity=cap, occupancy=[occ], staged=[staged],
                          spill=spill, spill_cap=spill_cap, step=step,
                          mode="fifo", lateness_p99=0.0, drain_per_step=4,
                          window_of=lambda r: 0)


def tier_sig(cap, occ, **kw):
    return PressureSignal(capacity=cap, occupancy=list(occ),
                          staged=[0] * len(occ), spill=kw.get("spill", 0),
                          spill_cap=kw.get("spill_cap", 4), step=0,
                          mode="tiers", lateness_p99=0.0, drain_per_step=4,
                          window_of=lambda r: r.prio)


def edf_sig(cap, occ, window_order, window_lo, *, step=0, lateness=0.0):
    los = sorted((lo, w) for w, lo in window_lo.items())

    def window_of(r):
        best = los[0][1]
        for lo, w in los:
            if r.deadline >= lo:
                best = w
        return best

    return PressureSignal(capacity=cap, occupancy=list(occ),
                          staged=[0] * len(occ), spill=0, spill_cap=4,
                          step=step, mode="edf", lateness_p99=lateness,
                          drain_per_step=4, window_of=window_of,
                          window_order=list(window_order),
                          window_lo=dict(window_lo))


# --------------------------------------------------------- policy core ----

def test_exactly_at_capacity_admits_all():
    """A batch that exactly fills live headroom is admitted whole — the
    boundary where one-off errors would either lose a slot or overflow."""
    sig = fifo_sig(cap=8, occ=3, staged=1)           # headroom = 4
    reqs = [R(i) for i in range(4)]
    dec = ShedPolicy().decide(reqs, sig)
    assert [r.rid for r in dec.admit] == [0, 1, 2, 3]
    assert dec.shed == [] and dec.defer == []
    assert sig.headroom(0) == 0                      # every slot reserved


def test_capacity_plus_one_sheds_latest_arrival_fifo():
    sig = fifo_sig(cap=8, occ=4)                     # headroom = 4
    reqs = [R(i) for i in range(5)]
    dec = ShedPolicy().decide(reqs, sig)
    assert [r.rid for r in dec.admit] == [0, 1, 2, 3]
    assert [r.rid for r in dec.shed] == [4]          # newest is the victim


def test_contended_tier_sheds_its_own_latest_not_other_tiers():
    """Victim selection is per-window: a full low tier sheds ITS latest
    arrival; the high tier with headroom is untouched."""
    sig = tier_sig(cap=2, occ=[0, 1])                # t0 room 2, t1 room 1
    reqs = [R(0, prio=0), R(1, prio=1), R(2, prio=0), R(3, prio=1)]
    dec = ShedPolicy().decide(reqs, sig)
    assert [r.rid for r in dec.admit] == [0, 1, 2]
    assert [r.rid for r in dec.shed] == [3]          # t1's later arrival


def test_edf_doomed_shed_before_meetable():
    """Within a contended bucket a deadline that is already unmeetable
    (behind now + lateness p99) sheds before a later-but-meetable one:
    serving it would spend capacity on a guaranteed miss."""
    sig = edf_sig(cap=1, occ=[0], window_order=[0], window_lo={0: 0},
                  step=10, lateness=2.0)
    doomed, meetable = R(0, deadline=11), R(1, deadline=20)
    dec = ShedPolicy().decide([doomed, meetable], sig)
    assert dec.admit == [meetable]
    assert dec.shed == [doomed]


def test_defer_overflow_is_structured_not_silent():
    """When the spill buffer cannot hold the overflow either, the excess
    is shed and COUNTED as spill_overflow — the engine surfaces it as
    AdmissionRejected(kind="spill-overflow"), never a silent drop."""
    sig = fifo_sig(cap=4, occ=4, spill=1, spill_cap=2)   # spill room = 1
    reqs = [R(i) for i in range(3)]
    dec = DeferPolicy().decide(reqs, sig)
    assert dec.admit == []
    assert [r.rid for r in dec.defer] == [0]
    assert [r.rid for r in dec.shed] == [1, 2]
    assert dec.spill_overflow == 2


def test_degrade_moves_tier_down_and_rewrites_prio():
    sig = tier_sig(cap=2, occ=[2, 0])                # t0 full, t1 free
    r = R(7, prio=0)
    dec = DegradePolicy().decide([r], sig)
    assert dec.admit == [r] and dec.degraded == 1
    assert r.prio == 1                               # visibly downgraded
    assert sig.headroom(1) == 1                      # slot reserved in t1


def test_degrade_edf_extends_deadline_along_key_order():
    """Seap bucket ids are NOT key-ordered; degrade must walk the
    directory's key order (window_order) and extend the deadline to the
    next bucket's lower bound — the smallest extension that moves it."""
    sig = edf_sig(cap=1, occ=[0, 0, 0], window_order=[2, 0, 1],
                  window_lo={2: 0, 0: 64, 1: 128})
    r = R(9, deadline=5)                             # lands in bucket 2
    dec = DegradePolicy().decide([R(8, deadline=3), r], sig)
    assert dec.degraded == 1 and r in dec.admit      # R(8) fit normally
    assert r.deadline == 64                          # next bucket's lo
    assert sig.headroom(0) == 0                      # slot reserved there


def test_degrade_falls_back_when_everything_full():
    sig = tier_sig(cap=1, occ=[1, 1])
    r = R(5, prio=0)
    shed_dec = DegradePolicy(fallback="shed").decide([r], sig)
    assert shed_dec.shed == [r] and shed_dec.degraded == 0
    sig2 = tier_sig(cap=1, occ=[1, 1])
    defer_dec = DegradePolicy(fallback="defer").decide([r], sig2)
    assert defer_dec.defer == [r]


def test_admit_order_is_arrival_order_even_after_urgency_sort():
    sig = tier_sig(cap=4, occ=[0, 0])
    reqs = [R(0, prio=1), R(1, prio=0), R(2, prio=1), R(3, prio=0)]
    dec = ShedPolicy().decide(reqs, sig)
    assert [r.rid for r in dec.admit] == [0, 1, 2, 3]


def test_resolve_policy():
    assert resolve_policy(None) is None
    assert isinstance(resolve_policy("shed"), ShedPolicy)
    p = DeferPolicy()
    assert resolve_policy(p) is p
    with pytest.raises(ValueError, match="unknown admission policy"):
        resolve_policy("yolo")
    with pytest.raises(ValueError, match="takes None"):
        resolve_policy(42)
    assert isinstance(AdmissionPolicy(), AdmissionPolicy)


# ---------------------------------------------------------- controller ----

def test_controller_flap_guard_on_square_wave():
    """A square-wave load whose half-period is shorter than the patience
    window must produce ZERO resizes: the streak counter resets every
    time the load crosses back over the watermark."""
    ctl = HysteresisController(high_patience=3, low_patience=3, cooldown=2)
    for cycle in range(10):                          # 2 high, 2 low, ...
        for util in (0.9, 0.9, 0.1, 0.1):
            assert ctl.observe(util, n_shards=4) is None
    snap = ctl.snapshot()
    assert snap["grows"] == 0 and snap["shrinks"] == 0


def test_controller_grows_then_cooldown_suppresses():
    ctl = HysteresisController(high_patience=2, cooldown=3, grow_k=1,
                               max_shards=8)
    assert ctl.observe(0.9, n_shards=2) is None
    assert ctl.observe(0.9, n_shards=2) == 3         # patience met -> grow
    ctl.notify_resize(3)
    for _ in range(3):                               # cooldown window
        assert ctl.observe(0.95, n_shards=3) is None
    assert ctl.snapshot()["suppressed_cooldown"] == 3
    assert ctl.observe(0.95, n_shards=3) is None     # patience restarts
    assert ctl.observe(0.95, n_shards=3) == 4


def test_controller_shrink_is_lazier_and_clamped():
    ctl = HysteresisController(high_patience=2, low_patience=4, cooldown=0,
                               min_shards=2)
    for _ in range(3):
        assert ctl.observe(0.05, n_shards=2) is None
    assert ctl.observe(0.05, n_shards=2) is None     # already at floor
    ctl2 = HysteresisController(low_patience=2, cooldown=0, min_shards=1)
    assert ctl2.observe(0.05, n_shards=3) is None
    assert ctl2.observe(0.05, n_shards=3) == 2


def test_controller_overloaded_flag_counts_as_high():
    """A step that shed/deferred counts as above-watermark even when the
    post-shed utilization reads low — shedding IS the overload signal."""
    ctl = HysteresisController(high_patience=2, cooldown=0, max_shards=4)
    assert ctl.observe(0.1, n_shards=2, overloaded=True) is None
    assert ctl.observe(0.1, n_shards=2, overloaded=True) == 3


def test_controller_external_resize_resets_and_counts():
    ctl = HysteresisController(high_patience=2, cooldown=4)
    ctl.observe(0.9, n_shards=4)
    ctl.notify_resize(3, external=True)              # fault LEAVEd a shard
    snap = ctl.snapshot()
    assert snap["external_resizes"] == 1 and snap["grows"] == 0
    assert ctl.observe(0.9, n_shards=3) is None      # cooldown holds


def test_controller_watermark_validation():
    with pytest.raises(ValueError):
        HysteresisController(high_watermark=0.2, low_watermark=0.5)
    with pytest.raises(ValueError):
        HysteresisController(ControllerConfig(low_watermark=-0.1))
    with pytest.raises(ValueError):
        HysteresisController(cooldown=-1)


# ------------------------------------------- policies x deadline engine ----

@pytest.fixture(scope="module")
def edf_parts():
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    cfg = get_config("mamba2_130m").reduced(n_layers=1)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    return model, params, make_host_mesh(n_data=1)


def _edf_engine(edf_parts, **kw):
    from repro.serve import ServeEngine
    model, params, mesh = edf_parts
    return ServeEngine(model, params, mesh, max_slots=2, max_seq=16,
                       queue_cap=4, deadline=True, n_buckets=4,
                       deadline_horizon=32, **kw)


def _burst(n, start_rid=0, deadline=24):
    from repro.serve import Request
    return [Request(rid=start_rid + i, prompt=[1, 2], max_new=2,
                    deadline=deadline) for i in range(n)]


def test_edf_engine_shed_rejects_structured_and_retryable(edf_parts):
    from repro.serve import AdmissionRejected
    eng = _edf_engine(edf_parts, admission="shed")
    big = _burst(64)                         # far beyond any bucket window
    with pytest.raises(AdmissionRejected) as ei:
        eng.submit(big)
    err = ei.value
    assert err.kind == "shed" and err.policy == "shed"
    assert err.admitted > 0 and len(err.shed) > 0
    assert err.admitted + len(err.shed) == len(big)
    assert err.retry_after >= 1
    # shed requests were never registered: the engine is untouched by them
    assert all(r.rid not in eng.requests for r in err.shed)
    assert eng.run_until_drained(max_steps=400)
    assert eng.stats["served"] == err.admitted
    # ... and resubmitting the victims later is safe (no double-admission)
    retry = err.shed[:2]
    eng.submit(retry, deadline=24)
    assert eng.run_until_drained(max_steps=400)
    assert eng.stats["served"] == err.admitted + len(retry)


def test_edf_engine_defer_spills_then_drains_lossless(edf_parts):
    eng = _edf_engine(edf_parts, admission="defer", spill_cap=64)
    big = _burst(24)
    eng.submit(big)                          # no raise: overflow spilled
    assert eng.admission_stats["deferred"] > 0
    assert eng.run_until_drained(max_steps=400)
    assert eng.stats["served"] == len(big)   # lossless within spill_cap
    assert all(r.done for r in big)


def test_edf_engine_degrade_extends_deadlines(edf_parts):
    eng = _edf_engine(edf_parts, admission=DegradePolicy(fallback="defer"))
    big = _burst(24, deadline=8)             # one hot near-term bucket
    eng.submit(big)
    assert eng.admission_stats["degraded"] > 0
    assert max(r.deadline for r in big) > 8  # visibly extended
    assert eng.run_until_drained(max_steps=400)
    assert eng.stats["served"] == len(big)


def test_edf_engine_no_policy_still_overflows(edf_parts):
    """The pre-PR 8 behavior is preserved when admission is off: a burst
    past the window capacity overflows the wave itself."""
    from repro.dqueue import QueueOverflowError
    eng = _edf_engine(edf_parts)
    eng.submit(_burst(64))
    with pytest.raises(QueueOverflowError):
        eng.run_until_drained(max_steps=400)
