"""int8 + error-feedback gradient compression (cross-pod DCN path)."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.train.grad_compress import (compress_grads, compression_ratio,
                                       decompress_grads)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.array(rng.standard_normal((256, 128)) * 0.01,
                           jnp.float32),
            "b": {"w": jnp.array(rng.standard_normal((1000,)), jnp.float32)}}


def test_roundtrip_error_bounded():
    g = _tree()
    payload, res = compress_grads(g, None)
    deq = decompress_grads(payload, g)
    for x, y in zip(jax.tree.leaves(g), jax.tree.leaves(deq)):
        scale = float(jnp.max(jnp.abs(x))) / 127
        assert float(jnp.max(jnp.abs(x - y))) <= scale * 1.01


def test_error_feedback_unbiased_over_time():
    """Accumulated (dequantized) updates converge to accumulated grads."""
    g = _tree(1)
    res = None
    total_true = jax.tree.map(jnp.zeros_like, g)
    total_sent = jax.tree.map(jnp.zeros_like, g)
    for step in range(30):
        gs = jax.tree.map(lambda x: x * (1 + 0.01 * step), g)
        payload, res = compress_grads(gs, res)
        deq = decompress_grads(payload, gs)
        total_true = jax.tree.map(lambda a, b: a + b, total_true, gs)
        total_sent = jax.tree.map(lambda a, b: a + b, total_sent, deq)
    for t, s, r in zip(jax.tree.leaves(total_true),
                       jax.tree.leaves(total_sent),
                       jax.tree.leaves(res)):
        # residual carries exactly the un-sent mass: true = sent + residual
        np.testing.assert_allclose(np.asarray(t), np.asarray(s + r),
                                   rtol=1e-4, atol=1e-5)


def test_compression_ratio():
    g = _tree(2)
    r = compression_ratio(g)
    assert 0.4 < r < 0.6  # ~int8 + block scales vs bf16
